"""End-to-end DiT sampling benchmark: the bf16 fused ring kernel,
sharded classifier-free guidance, and step-to-step feature caching, all
measured on the REAL registry executors driving a transformer denoiser.

    PYTHONPATH=src python benchmarks/bench_e2e_dit.py [--smoke]

Four claims are asserted (the PR's regression gate), one per section:

A. **bf16 fused ring kernel** — the fused-dual ring executor cuts
   ``cost_analysis()['bytes accessed']`` of the solve by >= 30% vs the
   concat-bf16 baseline (closing the f32/bf16 gap in the
   BENCH_RESULTS.json trajectory: bf16 was 19.5% before the bf16 tile
   banking). As in ``bench_hotpath``, XLA's bytes-accessed is the
   acceptance metric (asserted, on solver-only traffic with a trivial
   model at the DiT latent size) and the trip-count-aware per-step
   numbers from ``hlo_cost`` are the recorded physical-traffic view.
   The per-step view is *diluted* relative to the acceptance metric by
   traffic the two paths share — the per-step tau-noise RNG (threefry +
   erfinv; tau is traced data so it never specializes away) — and, at
   the rank-3 ``[B, S, dz]`` latent, by XLA loop-fusing the concat
   shift into the broadcast-multiply-reduce combine (the rank-1 dot
   cannot absorb operands like that), so both flat and rank-3 layouts
   are recorded. Attribution on the full DiT executor comes from the
   ``hlo_cost.region_bytes`` backbone/solver split (the Denoiser tags
   network ops with ``named_scope("backbone")``).

B. **sharded CFG** — the cond/uncond pair on a size-2 ``cfg`` mesh axis
   is (i) bitwise equal to the doubled-lane evaluation on a pure data
   mesh, (ii) bitwise equal to the unguided path at scale 1.0, and
   (iii) halves per-device network work: the cfg mesh runs each request
   at ONE lane per device where the doubled-lane path runs two, so
   per-partition backbone FLOPs drop by ~2x (asserted < 0.6x).

C. **feature caching** — DeepCache-style mid-block reuse
   (``SamplerSpec.feature_cache``) on a contractive 8-layer DiT
   (``repro.models.tame``) cuts backbone FLOPs >= 25% (trip-count-aware,
   refresh-vs-cached eval graphs weighted by the plan's refresh
   schedule) at a bounded quality delta (relative L2 vs the uncached
   solve < 0.05, and > 0 so the cache demonstrably engages).

D. **compile-cache contract** — a tau x guidance-scale x
   residual-threshold sweep over the guided + feature-cached executor
   costs exactly ONE compile: tau/threshold are plan data, the scale is
   traced data.

Every ``benchmarks.run`` invocation appends the metrics (wall time, HBM
bytes by region, backbone-eval counts) to ``BENCH_RESULTS.json``.
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.core import Denoiser, get_schedule
from repro.core import samplers
from repro.core.samplers import (SamplerSpec, Sampler, build_plan,
                                 get_family, sample_sharded)
from repro.core.samplers.base import warmup
from repro.launch.hlo_cost import analyze_compiled
from repro.models import build_model, init_params
from repro.models.tame import tame_dit, tame_networks

try:
    from .common import print_table  # python -m benchmarks.run
except ImportError:
    from common import print_table  # python benchmarks/bench_e2e_dit.py

SCHED = get_schedule("vp_linear")


def _trivial(x, t):
    return 0.97 * x  # isolates solver bookkeeping, as in bench_hotpath


def _spec(m: int, history: str, combine: str) -> SamplerSpec:
    return SamplerSpec(name="sa", schedule=SCHED, n_steps=m, tau=0.6,
                       predictor_order=3, corrector_order=3, mode="PEC",
                       history=history, combine=combine, precision="bf16")


def _compile_solver_only(history: str, combine: str, shape, m: int):
    """The bare registry executor (trivial model) at the e2e latent
    shape — solver bookkeeping is the only traffic."""
    plan = build_plan(_spec(m, history, combine))
    fam = get_family("sa")
    statics = plan.statics

    def run_fn(arrays, x, k):
        return fam.execute(statics, arrays, _trivial, x, k, False)

    proto = jax.random.PRNGKey(0)
    arrays_s = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), plan.arrays)
    x_s = jax.ShapeDtypeStruct(shape, jnp.float32)
    k_s = jax.ShapeDtypeStruct(proto.shape, proto.dtype)
    return jax.jit(run_fn).lower(arrays_s, x_s, k_s).compile()


def _xla_bytes(compiled) -> float:
    d = compiled.cost_analysis()
    d = d[0] if isinstance(d, list) else d  # list-of-dicts on older jax
    return float(d["bytes accessed"])


def _solver_only_per_step(history: str, combine: str, shape,
                          m_lo: int, m_hi: int) -> float:
    """Per-step HBM bytes of the bare executor, differenced across two
    step counts so init/final code cancels."""
    b_lo = analyze_compiled(_compile_solver_only(history, combine,
                                                 shape, m_lo)).bytes
    b_hi = analyze_compiled(_compile_solver_only(history, combine,
                                                 shape, m_hi)).bytes
    return (b_hi - b_lo) / (m_hi - m_lo)


def _dit_denoiser():
    """The standard smoke DiT-S behind the Denoiser adapter (x0 net)."""
    cfg = get_smoke("dit-s")
    model = build_model(cfg)
    params = init_params(jax.random.PRNGKey(0), model.param_defs(),
                         jnp.float32)

    def network(x, t, cond):
        lane = x.ndim == 2
        x0 = model.denoise(params, x[None] if lane else x, t)
        return x0[0] if lane else x0

    return cfg, Denoiser(network, SCHED, prediction="x0")


def _regions_per_step(den, variant: str, history: str, combine: str,
                      shape, m_lo: int, m_hi: int) -> dict:
    """Backbone/solver HBM bytes per step of the FULL DiT executor.

    The compile cache keys executors on everything *except* the step
    count and stores one AOT executable per key, so each (variant, m)
    pair gets its own ``model_key``."""
    per = {}
    for m in (m_lo, m_hi):
        plan = build_plan(_spec(m, history, combine))
        aot = warmup(plan, den, shape, jnp.float32,
                     model_key=("e2e-region", variant, m, shape))
        per[m] = analyze_compiled(aot).region_bytes
    return {k: (per[m_hi][k] - per[m_lo][k]) / (m_hi - m_lo)
            for k in ("backbone", "solver")}


def run(smoke: bool = False):
    metrics: dict = {}
    shape = (4, 32, 8) if smoke else (16, 64, 8)
    m_lo, m_hi = (4, 8) if smoke else (8, 16)

    # ---------------- A. bf16 fused ring kernel: HBM per step ----------
    variants = [("concat_bf16", "concat", "einsum"),
                ("fused_bf16", "ring", "fused")]
    flat = (int(np.prod(shape)),)
    rows = []
    for name, hist, comb in variants:
        metrics[f"{name}_xla_bytes"] = _xla_bytes(
            _compile_solver_only(hist, comb, flat, m_hi))
        for lay, sh in [("flat", flat), ("rank3", shape)]:
            b = _solver_only_per_step(hist, comb, sh, m_lo, m_hi)
            metrics[f"{name}_{lay}_solver_per_step"] = b
            rows.append([f"{name} {lay}{list(sh)}", b / 2**10])
    xla_drop = 1.0 - (metrics["fused_bf16_xla_bytes"]
                      / metrics["concat_bf16_xla_bytes"])
    metrics["fused_bf16_xla_drop"] = round(xla_drop, 4)
    drops = {}
    for lay in ("flat", "rank3"):
        drops[lay] = 1.0 - (metrics[f"fused_bf16_{lay}_solver_per_step"]
                            / metrics[f"concat_bf16_{lay}_solver_per_step"])
        metrics[f"fused_bf16_{lay}_solver_drop"] = round(drops[lay], 4)
    print_table("solver HBM per step at the DiT latent size "
                "(trivial model)", ["path", "KiB/step"], rows)
    print(f"cost_analysis() bytes-accessed drop, fused bf16 vs concat "
          f"bf16: {xla_drop:.1%} (claim: >= 30%); trip-aware per-step "
          f"drop {drops['flat']:.1%} flat, {drops['rank3']:.1%} rank-3 "
          "(RNG- and fusion-diluted — see module doc)")
    assert xla_drop >= 0.30, (
        f"fused bf16 ring path cuts cost_analysis() bytes by only "
        f"{xla_drop:.1%} vs concat bf16 (claimed >= 30%)")

    cfg, den = _dit_denoiser()
    rows = []
    for name, hist, comb in variants:
        reg = _regions_per_step(den, name, hist, comb, shape, m_lo, m_hi)
        metrics[f"{name}_e2e_backbone_per_step"] = reg["backbone"]
        metrics[f"{name}_e2e_solver_per_step"] = reg["solver"]
        rows.append([name, reg["backbone"] / 2**10, reg["solver"] / 2**10])
    e2e_drop = 1.0 - (metrics["fused_bf16_e2e_solver_per_step"]
                      / metrics["concat_bf16_e2e_solver_per_step"])
    metrics["fused_bf16_e2e_solver_drop"] = round(e2e_drop, 4)
    share = (metrics["fused_bf16_e2e_backbone_per_step"]
             / (metrics["fused_bf16_e2e_backbone_per_step"]
                + metrics["fused_bf16_e2e_solver_per_step"]))
    metrics["e2e_backbone_byte_share"] = round(share, 4)
    print_table(
        f"full DiT-S executor HBM per step, region split ({shape})",
        ["path", "backbone KiB/step", "solver KiB/step"], rows)
    print(f"e2e solver-region drop {e2e_drop:.1%} (diluted by shared "
          f"per-step tau RNG); backbone share of e2e bytes {share:.1%}")

    # ---------------- B. sharded CFG -----------------------------------
    ndev = len(jax.devices())
    if ndev < 2 or ndev % 2:
        raise AssertionError(
            f"sharded-CFG section needs an even device count >= 2, have "
            f"{ndev} (CI runs with --xla_force_host_platform_device_count=8)")
    from repro.serve.sharding import auto_cfg_mesh
    # a conditional DiT (the smoke config grows a denoiser_cond input):
    # adaLN-zero init makes blocks identity, so perturb the params to get
    # a network whose cond branch genuinely differs from uncond
    cfg_g = dataclasses.replace(get_smoke("dit-s"), n_layers=4,
                                denoiser_cond=4)
    model_g = build_model(cfg_g)
    params_g = init_params(jax.random.PRNGKey(0), model_g.param_defs(),
                           jnp.float32)
    params_g = jax.tree.map(
        lambda p: p + 0.02 * jax.random.normal(
            jax.random.PRNGKey(1), p.shape, p.dtype), params_g)

    def net_g(x, t, c):
        lane = x.ndim == 2
        if c is not None and lane and c.ndim == 1:
            c = c[None]
        x0 = model_g.denoise(params_g, x[None] if lane else x, t, c)
        return x0[0] if lane else x0

    den_g = Denoiser(net_g, SCHED, prediction="x0", guidance=True)
    den_u = Denoiser(net_g, SCHED, prediction="x0", guidance=False)

    B, S, dz = ndev, 16, 8
    spec_u = SamplerSpec.from_nfe("sa", 8, schedule=SCHED, tau=0.0)
    spec_g = dataclasses.replace(spec_u, guidance=True)
    plan_u, plan_g = build_plan(spec_u), build_plan(spec_g)
    xT = Sampler(spec_g).init_noise(jax.random.PRNGKey(5), (B, S, dz))
    cond = jnp.ones((B, 4), jnp.float32)
    keys = jax.vmap(jax.random.fold_in, (None, 0))(
        jax.random.PRNGKey(7), jnp.arange(B))
    scales = jnp.full((B,), 2.5)
    data_mesh = jax.make_mesh((ndev,), ("data",))
    cfg_mesh = auto_cfg_mesh()

    out_d = sample_sharded(plan_g, den_g, xT, keys, mesh=data_mesh,
                           cond=cond, guidance_scale=scales)
    out_c = sample_sharded(plan_g, den_g, xT, keys, mesh=cfg_mesh,
                           cfg_axis="cfg", cond=cond, guidance_scale=scales)
    dev = float(jnp.abs(out_d - out_c).max())
    metrics["cfg_shard_max_abs_dev"] = dev
    assert jnp.array_equal(out_d, out_c), (
        f"sharded CFG deviates from doubled-lane CFG by {dev}")

    # the s = 1.0 combine claim is bitwise — (1-s)*u + s*c at s = 1
    # reproduces the cond branch exactly — and holds across meshes
    out_u = sample_sharded(plan_u, den_u, xT, keys, mesh=data_mesh,
                           cond=cond)
    out_s1 = sample_sharded(plan_g, den_g, xT, keys, mesh=cfg_mesh,
                            cfg_axis="cfg", cond=cond,
                            guidance_scale=jnp.ones((B,)))
    assert jnp.array_equal(out_s1, out_u), (
        "guided path at scale 1.0 is not bitwise the unguided path")
    print(f"sharded CFG: bitwise == doubled-lane ({B} requests, "
          f"{cfg_mesh.devices.shape} mesh); s=1.0 bitwise == unguided")

    # per-device work: doubled-lane on half the devices vs the cfg mesh
    # over all of them — same global batch, the cfg axis is parallelism
    # the data axis cannot reach (2 lanes/request/device -> 1)
    half = jax.make_mesh((ndev // 2,), ("data",),
                         devices=jax.devices()[:ndev // 2])
    cond_s = jax.ShapeDtypeStruct((4,), jnp.float32)
    fl = {}
    for tag, mesh, cax in [("lane_doubled", half, None),
                           ("cfg_sharded", cfg_mesh, "cfg")]:
        aot = warmup(plan_g, den_g, (S, dz), batch=B, mesh=mesh,
                     cfg_axis=cax, cond=cond_s,
                     model_key=("e2e-cfg-flops", tag))
        fl[tag] = analyze_compiled(aot).flops
    ratio = fl["cfg_sharded"] / fl["lane_doubled"]
    metrics["cfg_shard_flops_per_device_ratio"] = round(ratio, 4)
    metrics["cfg_shard_local_lanes"] = B // (ndev // 2)
    metrics["lane_doubled_local_lanes"] = 2 * B // (ndev // 2)
    print(f"per-device backbone flops, cfg-sharded / doubled-lane: "
          f"{ratio:.3f} (local lanes {metrics['cfg_shard_local_lanes']} "
          f"vs {metrics['lane_doubled_local_lanes']}; claim < 0.6)")
    assert ratio < 0.6, (
        f"cfg-sharded per-device flops ratio {ratio:.3f} (claimed < 0.6)")

    # ---------------- C. feature caching -------------------------------
    model_c, params_c, mu_c = tame_dit(n_layers=8)
    net_c, cached_c = tame_networks(model_c, params_c, mu_c)
    den_c = Denoiser(net_c, SCHED, prediction="x0", cached=cached_c)
    Bc, Sc = (2, 16) if smoke else (4, 32)
    nfe = 8 if smoke else 10
    spec0 = SamplerSpec.from_nfe("sa", nfe, schedule=SCHED, tau=0.0)
    xTc = Sampler(spec0).init_noise(jax.random.PRNGKey(8), (Bc, Sc, dz))
    kc = jax.random.PRNGKey(9)
    ref = Sampler(spec0).sample(den_c, xTc, kc)

    def eval_flops(refresh: bool) -> float:
        feats = cached_c.init(jnp.zeros((Bc, Sc, dz)))
        f = jax.jit(lambda x, fe: cached_c.call(
            x, jnp.float32(0.5), None, fe, refresh))
        comp = f.lower(
            jax.ShapeDtypeStruct((Bc, Sc, dz), jnp.float32),
            jax.ShapeDtypeStruct(feats.shape, feats.dtype)).compile()
        return analyze_compiled(comp).flops

    f_refresh, f_cached = eval_flops(True), eval_flops(False)
    metrics["fc_eval_flops_ratio"] = round(f_cached / f_refresh, 4)
    rows = []
    for fc in (2, ("residual", 0.05)):
        spec_fc = dataclasses.replace(spec0, feature_cache=fc)
        out = Sampler(spec_fc).sample(den_c, xTc, kc)
        rel = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
        slug = "interval" if fc == 2 else "residual"
        metrics[f"fc_{slug}_rel_dev"] = rel
        assert 0.0 < rel < 0.05, (
            f"feature_cache={fc}: rel dev {rel:.4f} outside (0, 0.05) — "
            "either the cache never engaged or quality is unbounded")
        refresh = np.asarray(build_plan(spec_fc).arrays["fc_refresh"])
        n_r = 1 + int(refresh.sum())     # the init eval always refreshes
        n_c = refresh.size - int(refresh.sum())
        rows.append([slug, n_r, n_c, rel])
        if slug == "interval":           # host-known refresh schedule
            red = 1.0 - (n_r * f_refresh + n_c * f_cached) / (
                (n_r + n_c) * f_refresh)
            metrics["fc_backbone_flop_reduction"] = round(red, 4)
            metrics["fc_refresh_evals"] = n_r
            metrics["fc_cached_evals"] = n_c
    print_table(
        f"feature caching, 8-layer contractive DiT, NFE={nfe} "
        f"(planned backbone evals)",
        ["policy", "refresh evals", "cached evals", "rel dev"], rows)
    red = metrics["fc_backbone_flop_reduction"]
    print(f"backbone flop reduction (interval k=2): {red:.1%} at rel dev "
          f"{metrics['fc_interval_rel_dev']:.2e} (claim: >= 25%, < 0.05)")
    assert red >= 0.25, (
        f"feature caching cuts backbone flops by only {red:.1%} "
        "(claimed >= 25%)")

    # ---------------- D. compile-cache contract ------------------------
    den_cg = Denoiser(net_c, SCHED, prediction="x0", guidance=True,
                      cached=cached_c)
    cond_c = 0.3 * jax.random.normal(jax.random.PRNGKey(10), (Bc, Sc, dz))
    samplers.clear_compile_cache()
    n_calls = 0
    for tau in (0.0, 0.6, 1.2):
        for s in (1.0, 2.5, 4.0):
            for thresh in (0.02, 0.08):
                spec_s = SamplerSpec.from_nfe(
                    "sa", nfe, schedule=SCHED, tau=tau, guidance=True,
                    feature_cache=("residual", thresh))
                Sampler(spec_s).sample(den_cg, xTc, kc, cond=cond_c,
                                       guidance_scale=s,
                                       model_key="e2e-sweep")
                n_calls += 1
    stats = samplers.compile_cache_stats()
    metrics["sweep_calls"] = n_calls
    metrics["sweep_misses"] = stats["misses"]
    print(f"tau x scale x threshold sweep ({n_calls} solves, guided + "
          f"cached executor): compile-cache misses = {stats['misses']}, "
          f"hits = {stats['hits']}")
    assert stats["misses"] == 1, (
        f"sweep recompiled: {stats['misses']} misses (expected 1)")

    # ---------------- E. wall time -------------------------------------
    if not smoke:
        spec_t = _spec(m_hi, "ring", "fused")
        sampler_t = Sampler(spec_t)
        xt = sampler_t.init_noise(jax.random.PRNGKey(11), shape)
        kt = jax.random.PRNGKey(12)
        jax.block_until_ready(
            sampler_t.sample(den, xt, kt, model_key="e2e-time"))
        t0 = time.perf_counter()
        runs = 0
        while time.perf_counter() - t0 < 0.6:
            jax.block_until_ready(
                sampler_t.sample(den, xt, kt, model_key="e2e-time"))
            runs += 1
        ms = (time.perf_counter() - t0) / max(runs, 1) * 1e3
        metrics["e2e_ms_per_solve"] = round(ms, 3)
        print(f"e2e DiT-S fused-bf16 solve ({shape}, {m_hi} steps): "
              f"{ms:.2f} ms")
    metrics["shape"] = list(shape)
    metrics["n_steps"] = m_hi
    return metrics


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: smaller shapes, skip wall-time loops")
    args = ap.parse_args()
    run(smoke=args.smoke)
    print("e2e DiT claims OK")
