"""Hot-path benchmark: per-step HBM bytes + step latency for the SA-Solver
executor, concat vs ring vs fused-dual history, f32 vs bf16.

    PYTHONPATH=src python benchmarks/bench_hotpath.py [--smoke]

What is measured (PEC-with-corrector, P = 3, the paper's default — the
worst case for history traffic) on the *real* registry executors with a
trivial model, so the numbers isolate solver bookkeeping:

- ``xla_bytes``: raw ``compiled.cost_analysis()['bytes accessed']`` of the
  whole jitted solve (XLA counts the scan body once). This is the
  acceptance metric: the fused-dual ring path must cut it by >= 30% vs.
  the seed concat executor at f32.
- ``hbm_per_step``: trip-count-aware per-step HBM bytes from
  ``repro.launch.hlo_cost.analyze_compiled`` (dynamic-update-slice charged
  at the row it writes, the way the aliased in-loop update actually
  behaves), differenced across two step counts so init/final code cancels.
  This is the physical-traffic number the README table quotes.
- ``ms_per_solve``: steady-state wall time of the compiled solve.

Also asserted here, because this benchmark is the PR's regression gate:

- the f32 ring (einsum) executor is **bitwise identical** to the seed
  concat executor;
- a tau sweep at fixed step count causes **zero** new compile-cache
  misses on the ring path (tau is traced data, the ring head is derived
  from the step index — nothing about the ring re-keys the cache).

Every ``benchmarks.run`` invocation appends these metrics to
``BENCH_RESULTS.json`` — the perf trajectory across PRs.
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core.samplers import (SamplerSpec, build_plan, get_family,
                                 make_sampler)
from repro.core import samplers
from repro.launch.hlo_cost import analyze_compiled

try:
    from .common import print_table  # python -m benchmarks.run
except ImportError:
    from common import print_table  # python benchmarks/bench_hotpath.py


def _model(x, t):
    return 0.97 * x  # trivial data-prediction model: solver cost dominates


def analytic_per_step(P: int, elem_bytes: int, n: int) -> dict:
    """Ideal-fusion HBM model for one PEC-with-corrector step (model eval
    and RNG excluded — identical across paths), in bytes, for a [n]
    latent. Counts full-array passes: each combine reads its operands
    once and writes once (the Pallas kernels' contract; XLA approaches it
    with loop fusion).

    concat (seed): predictor reads x, xi, P rows -> x_pred (P+3);
    corrector reads x, xi, e_new, P rows -> x_next (P+4); the shift
    re-materializes the buffer: P rows read + P written (2P).
    ring: same combines but the shift is ONE row write (1).
    fused ring: one pass reads x, xi, P rows and writes BOTH partial sums
    (P+4); the post-eval corrector touches corr_base + e_new -> x_next
    (3); one row write (1).
    """
    unit = n * elem_bytes
    return {
        "concat": (4 * P + 7) * unit,
        "ring": (2 * P + 8) * unit,
        "fused": (P + 8) * unit,
    }


def _cost_bytes(compiled) -> float:
    d = compiled.cost_analysis()
    d = d[0] if isinstance(d, list) else d  # list-of-dicts on older jax
    return float(d["bytes accessed"])


def _compile_solve(spec: SamplerSpec, n: int):
    """AOT-compile the registry executor for a [n] latent (the real
    ``execute_sa``, not a re-implementation)."""
    plan = build_plan(spec)
    fam = get_family(spec.name)
    statics = plan.statics

    def run(arrays, x, k):
        return fam.execute(statics, arrays, _model, x, k, False)

    proto = jax.random.PRNGKey(0)
    arrays_s = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), plan.arrays)
    x_s = jax.ShapeDtypeStruct((n,), jnp.float32)
    k_s = jax.ShapeDtypeStruct(proto.shape, proto.dtype)
    return jax.jit(run).lower(arrays_s, x_s, k_s).compile(), plan


def _hbm_per_step(spec: SamplerSpec, n: int, m1: int, m2: int,
                  compiled_m2) -> float:
    """Per-step HBM bytes: difference the trip-count-aware totals at two
    step counts so everything outside the scan body cancels.
    ``compiled_m2`` is the already-compiled m2-step executor (re-lowering
    it here would double every variant's compile time)."""
    c1, _ = _compile_solve(spec.replace(n_steps=m1), n)
    return (analyze_compiled(compiled_m2).bytes
            - analyze_compiled(c1).bytes) / (m2 - m1)


def _time_solve(compiled, plan, x, key, budget_s: float = 0.6) -> float:
    out = jax.block_until_ready(compiled(plan.arrays, x, key))
    t0 = time.perf_counter()
    runs = 0
    while time.perf_counter() - t0 < budget_s:
        out = jax.block_until_ready(compiled(plan.arrays, x, key))
        runs += 1
    del out
    return (time.perf_counter() - t0) / max(runs, 1) * 1e3


def run(smoke: bool = False):
    n = 1 << 16
    m = 8 if smoke else 20
    m_lo = max(2, m // 2)
    base = dict(schedule="vp_linear", n_steps=m, tau=0.6,
                predictor_order=3, corrector_order=3, mode="PEC")
    variants = [
        ("concat f32", SamplerSpec(name="sa", history="concat", **base)),
        ("ring f32", SamplerSpec(name="sa", history="ring", **base)),
        ("fused f32", SamplerSpec(name="sa", combine="fused", **base)),
        ("fused bf16", SamplerSpec(name="sa", combine="fused",
                                   precision="bf16", **base)),
        ("concat bf16", SamplerSpec(name="sa", history="concat",
                                    precision="bf16", **base)),
    ]

    x = jax.random.normal(jax.random.PRNGKey(1), (n,), jnp.float32)
    key = jax.random.PRNGKey(2)
    rows, metrics = [], {"n": n, "n_steps": m}
    outputs = {}
    for name, spec in variants:
        compiled, plan = _compile_solve(spec, n)
        xla_b = _cost_bytes(compiled)
        hbm_step = _hbm_per_step(spec, n, m_lo, m, compiled)
        ms = 0.0 if smoke else _time_solve(compiled, plan, x, key)
        outputs[name] = compiled(plan.arrays, x, key)
        slug = name.replace(" ", "_")
        metrics[f"{slug}_xla_bytes"] = xla_b
        metrics[f"{slug}_hbm_per_step"] = hbm_step
        if not smoke:
            metrics[f"{slug}_ms"] = ms
        rows.append([name, xla_b / 2**20, hbm_step / 2**20, ms])
    print_table(
        f"SA hot path, PEC+corrector P=3, latent n=2^{n.bit_length()-1}, "
        f"{m} steps (trivial model)",
        ["path", "xla MiB (solve)", "hbm MiB/step", "ms/solve"], rows)

    # ideal-fusion HBM model (solver traffic only; what the Pallas
    # kernels deliver on TPU) — the README "Hot-path performance" table
    an_rows = []
    ref_f32 = analytic_per_step(3, 4, n)["concat"]
    for label, eb in [("f32", 4), ("bf16", 2)]:
        an = analytic_per_step(3, eb, n)
        for path in ("concat", "ring", "fused"):
            metrics[f"analytic_{path}_{label}_per_step"] = an[path]
            an_rows.append([f"{path} {label}", an[path] / 2**20,
                            an[path] / ref_f32])
    print_table("analytic per-step HBM (P=3, model/RNG excluded, "
                "x concat-f32)",
                ["path", "MiB/step", "frac of concat f32"], an_rows)

    ref = metrics["concat_f32_xla_bytes"]
    drop_fused = 1.0 - metrics["fused_f32_xla_bytes"] / ref
    hbm_drop_fused = 1.0 - (metrics["fused_f32_hbm_per_step"]
                            / metrics["concat_f32_hbm_per_step"])
    hbm_drop_bf16 = 1.0 - (metrics["fused_bf16_hbm_per_step"]
                           / metrics["concat_f32_hbm_per_step"])
    metrics["fused_f32_xla_drop"] = round(drop_fused, 4)
    metrics["fused_f32_hbm_drop"] = round(hbm_drop_fused, 4)
    metrics["fused_bf16_hbm_drop"] = round(hbm_drop_bf16, 4)
    print(f"cost_analysis() bytes-accessed drop, fused f32 vs concat f32: "
          f"{drop_fused:.1%} (claim: >= 30%)")
    print(f"per-step HBM drop (trip-aware): fused f32 {hbm_drop_fused:.1%}, "
          f"fused bf16 {hbm_drop_bf16:.1%}")
    assert drop_fused >= 0.30, (
        f"fused-dual ring path cuts cost_analysis() bytes by only "
        f"{drop_fused:.1%} vs the concat executor (claimed >= 30%)")
    assert hbm_drop_fused >= 0.30, (
        f"per-step HBM bytes (trip-aware) drop {hbm_drop_fused:.1%} < 30%")

    bitwise = bool(jnp.all(outputs["ring f32"] == outputs["concat f32"]))
    metrics["ring_f32_bitwise"] = bitwise
    assert bitwise, "f32 ring executor is not bitwise-equal to concat seed"
    fused_dev = float(jnp.max(jnp.abs(outputs["fused f32"]
                                      - outputs["concat f32"])))
    metrics["fused_f32_max_abs_dev"] = fused_dev
    assert fused_dev < 1e-3, f"fused path deviates by {fused_dev}"

    # tau sweep at fixed step count: plan changes, executor must not —
    # the ring head is derived from the step index, never from tau
    samplers.clear_compile_cache()
    xt = jax.random.normal(jax.random.PRNGKey(3), (4096,), jnp.float32)
    for tau in (0.0, 0.4, 0.8, 1.2, 1.6, 2.0):
        s = make_sampler("sa", schedule="vp_linear", n_steps=6, tau=tau)
        jax.block_until_ready(s.sample(_model, xt, key, model_key="bench"))
    stats = samplers.compile_cache_stats()
    metrics["tau_sweep_misses"] = stats["misses"]
    print(f"tau sweep (6 values, fixed steps): compile-cache misses = "
          f"{stats['misses']}, hits = {stats['hits']}")
    assert stats["misses"] == 1, (
        f"tau sweep recompiled: {stats['misses']} misses (expected 1)")
    return metrics


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: skip wall-time loops, fewer steps")
    args = ap.parse_args()
    run(smoke=args.smoke)
    print("hotpath claims OK")
