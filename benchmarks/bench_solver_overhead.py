"""Solver-step overhead: SA-Solver bookkeeping (buffer shifts + combine)
relative to the model evaluation it wraps. The paper's premise is that
multistep methods amortize expensive model calls; this measures the
amortization directly with a real (tiny DiT) denoiser."""

import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.core import SASolver, SASolverConfig, get_schedule
from repro.models import build_model, init_params

from .common import print_table


def run():
    sched = get_schedule("vp_linear")
    cfg = get_smoke("dit-s")
    model = build_model(cfg)
    params = init_params(jax.random.PRNGKey(0), model.param_defs(),
                         jnp.float32)
    dz = cfg.denoiser_latent
    B, S = 8, 32
    model_fn = lambda x, t: model.denoise(params, x, t)
    ident_fn = lambda x, t: x  # zero-cost "model": isolates solver overhead

    rows = []
    for nfe in (10, 20):
        scfg = SASolverConfig(n_steps=nfe - 1, predictor_order=3,
                              corrector_order=3, tau=1.0)
        solver = SASolver(sched, scfg)
        xT = solver.init_noise(jax.random.PRNGKey(1), (B, S, dz))

        def run_with(fn):
            f = jax.jit(lambda x, k: solver.sample(fn, x, k))
            f(xT, jax.random.PRNGKey(2))  # compile
            t0 = time.perf_counter()
            for r in range(3):
                jax.block_until_ready(f(xT, jax.random.PRNGKey(3 + r)))
            return (time.perf_counter() - t0) / 3

        t_model = run_with(model_fn)
        t_solver = run_with(ident_fn)
        rows.append([nfe, t_model * 1e3, t_solver * 1e3,
                     100.0 * t_solver / t_model])
    print_table("solver bookkeeping overhead (tiny DiT denoiser)",
                ["NFE", "full_ms", "solver_only_ms", "overhead_%"], rows)
    assert rows[-1][-1] < 50.0, "solver overhead must be minor vs model eval"
    return rows


if __name__ == "__main__":
    run()
