"""Theorems 5.1 / 5.2: strong convergence order, measured.

Per-trajectory error vs a 640-step reference under shared Brownian draws
(tau=0 => deterministic; the multistep order shows directly)."""

import jax
import jax.numpy as jnp
import numpy as np

from .common import print_table, sa_run


def run():
    ref = sa_run(641, 3, 3, 0.0)
    rows = []
    for (p, c) in [(1, 0), (2, 0), (3, 0), (1, 1), (2, 2), (3, 3)]:
        errs = []
        for n in (21, 41, 81):
            x = sa_run(n, p, c, 0.0)
            errs.append(float(jnp.mean(jnp.linalg.norm(x - ref, axis=-1))))
        order = float(np.log2(errs[0] / errs[-1]) / 2.0)
        rows.append([f"P{p}C{c}"] + errs + [order])
    print_table("Thm 5.1/5.2: strong error vs steps (tau=0)",
                ["scheme", "err@20", "err@40", "err@80", "observed order"],
                rows)
    orders = {r[0]: r[-1] for r in rows}
    assert orders["P1C0"] > 0.7
    assert orders["P2C0"] > 1.6
    assert orders["P3C0"] > 2.4
    assert orders["P3C3"] > orders["P3C0"] - 0.3  # corrector >= predictor
    return rows


if __name__ == "__main__":
    run()
