"""Paper Table 2: predictor steps / corrector ablation.

Claim reproduced: multistep (3-step vs 1-step) and the corrector both
improve quality at matched (NFE, tau) cells."""

from .common import print_table, quality, sa_run

CELLS = [(15, 0.4), (23, 0.8), (31, 1.0), (47, 1.4)]
SETTINGS = [
    ("P1 only", 1, 0),
    ("P1 + C1", 1, 1),
    ("P3 only", 3, 0),
    ("P3 + C3", 3, 3),
]


def run():
    rows = []
    for label, p, c in SETTINGS:
        row = [label]
        for nfe, tau in CELLS:
            row.append(quality(sa_run(nfe, p, c, tau))["sw2"])
        rows.append(row)
    print_table("Table 2 analogue: predictor/corrector ablation (sliced-W2)",
                ["setting"] + [f"NFE{n},tau{t}" for n, t in CELLS], rows)
    # paper's orderings: P3 < P1; corrector helps the 1-step solver
    assert rows[2][3] < rows[0][3], "P3 must beat P1 at NFE=31"
    assert rows[1][3] < rows[0][3], "C1 must improve P1 at NFE=31"
    return rows


if __name__ == "__main__":
    run()
