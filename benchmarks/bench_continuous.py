"""Continuous batching vs solve-granular serving on a straggler mix.

    PYTHONPATH=src python benchmarks/bench_continuous.py --smoke
    PYTHONPATH=src python benchmarks/bench_continuous.py --requests 48

The workload the step scheduler exists for: most requests are "easy"
(a generous early-exit tolerance retires them a few steps in) while a
straggler minority runs the full solve. The solve-granular engine pays
full NFE for every lane and a straggler microbatch blocks the queue; the
step scheduler recycles every freed lane at the next step boundary and
keeps stragglers from convoying the easy traffic.

Reports (and asserts under ``--smoke``):

- **scheduler head-to-head** — requests/s and model-evals spent, same
  request stream through both schedulers; the smoke gate is the PR's
  acceptance bar (step >= 1.3x solve requests/s),
- **lane utilization** — per-bucket occupancy and wasted padded-lane
  steps from ``stats()["buckets"]`` (both schedulers report the same
  shape of numbers),
- **churn cache contract** — five drain-and-refill waves with re-planned
  taus through recycled lanes must add ZERO stepwise-cache misses after
  the first warmup: the step function is keyed by compiled identity, not
  by batch membership.
"""

import argparse
import time


def _args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes + assert speedup and cache "
                    "contract (CI)")
    ap.add_argument("--arch", default="dit-s")
    ap.add_argument("--requests", type=int, default=None,
                    help="total requests (5/6 easy, 1/6 stragglers)")
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--lanes", type=int, default=8)
    return ap.parse_args(argv)


def main(argv=None):
    args = _args(argv)

    from repro.core import get_schedule
    from repro.core.samplers import (clear_stepwise_cache, SamplerSpec,
                                     stepwise_cache_stats)
    from repro.launch.serve import build_denoiser_model_fn
    from repro.serve import ServeEngine

    try:
        from .common import print_table
    except ImportError:
        from common import print_table

    # seq keeps the per-eval device work large enough that the measured
    # ratio reflects model evals saved (early exit + recycling), not
    # per-tick host dispatch overhead
    n_req = args.requests or (24 if args.smoke else 48)
    seq = args.seq or 96
    n_straggle = max(1, n_req // 6)
    cfg, model_fn = build_denoiser_model_fn(args.arch, 8, smoke=True)
    schedule = get_schedule("vp_linear")
    shape = (seq, cfg.denoiser_latent)
    model_key = ("bench_continuous", cfg.name)
    spec = SamplerSpec(name="sa", schedule=schedule, n_steps=10,
                       mode="PECE", corrector_order=1, tau=0.6)

    def submit_mix(engine):
        """Interleave stragglers through the easy traffic — worst case
        for solve-granular convoys, steady state for lane recycling."""
        for i in range(n_req):
            if i % (n_req // n_straggle) == 0 and n_straggle > 0:
                engine.submit(spec, shape)               # full solve
            else:
                engine.submit(spec, shape,               # early-exits
                              early_exit_tol=1e3, min_steps=2)
        t0 = time.perf_counter()
        res = engine.run()
        dt = time.perf_counter() - t0
        assert len(res) == n_req
        return dt, res

    # ------------------------------------------------- scheduler head-to-head
    metrics = {"requests": n_req, "stragglers": n_straggle,
               "n_steps": spec.n_steps}
    engines = {
        "solve": ServeEngine(model_fn, model_key=model_key,
                             bucket_sizes=(args.lanes,)),
        "step": ServeEngine(model_fn, model_key=model_key,
                            scheduler="step", lanes=args.lanes),
    }
    best, last = {}, {}
    for sched, engine in engines.items():
        submit_mix(engine)                    # cold pass: compiles
    for _ in range(4):
        # interleaved warm passes, best-of per scheduler: these passes
        # are tens of ms, so back-to-back sampling of one scheduler is
        # hostage to noise bursts on a shared box — alternating spreads
        # any burst across both sides of the ratio
        for sched, engine in engines.items():
            dt, res = submit_mix(engine)
            best[sched] = min(best.get(sched, dt), dt)
            last[sched] = res
    rows = []
    for sched, engine in engines.items():
        warm_dt, res = best[sched], last[sched]
        s = engine.stats()
        label = f"{spec.name}/{spec.n_steps}step/" \
                f"{'x'.join(str(d) for d in shape)}/float32"
        b = s["buckets"][label]
        steps = sorted({r.n_steps for r in res if r.n_steps is not None})
        rows.append([sched, n_req / warm_dt, s["model_evals"],
                     f"{b['occupancy']:.2f}", b["wasted_lane_steps"],
                     steps or "-"])
        metrics[f"requests_per_s_{sched}"] = n_req / warm_dt
        metrics[f"occupancy_{sched}"] = b["occupancy"]
        metrics[f"wasted_lane_steps_{sched}"] = b["wasted_lane_steps"]
    print_table(
        f"scheduler head-to-head ({n_req} requests, {n_straggle} "
        f"stragglers at full {spec.n_steps} steps, easy lanes exit "
        f"at ~2; lanes={args.lanes}, arch={cfg.name}, warm pass)",
        ["scheduler", "req/s", "model-evals", "occupancy", "wasted",
         "steps-taken"], rows)
    speedup = metrics["requests_per_s_step"] / \
        metrics["requests_per_s_solve"]
    metrics["speedup"] = speedup
    print(f"\nstep/solve speedup on the straggler mix: {speedup:.2f}x")

    # ---------------------------------------------- churn cache contract
    clear_stepwise_cache()
    engine = ServeEngine(model_fn, model_key=model_key, scheduler="step",
                         lanes=args.lanes)
    submit_mix(engine)
    warmed = stepwise_cache_stats()
    for tau in (0.2, 0.5, 0.8, 1.1, 1.4):
        for _ in range(args.lanes + 1):  # forces recycling each wave
            engine.submit(spec.replace(tau=tau), shape,
                          early_exit_tol=1e3, min_steps=2)
        engine.run()
    after = stepwise_cache_stats()
    new_misses = after["misses"] - warmed["misses"]
    metrics["churn_cache_misses"] = new_misses
    print(f"\n### churn cache contract\nafter warmup: {warmed}\n"
          f"after 5 drain/refill waves (tau re-planned each wave): "
          f"{after}\nnew misses across churn: {new_misses}")

    if args.smoke:
        assert new_misses == 0, (
            f"join/leave churn re-compiled ({new_misses} new stepwise "
            "misses) — warmup is no longer keyed by the step function")
        assert speedup >= 1.3, (
            f"step scheduler {speedup:.2f}x vs solve on the straggler "
            "mix; acceptance bar is 1.3x")
        print(f"smoke OK: {speedup:.2f}x >= 1.3x, zero churn misses")
    return metrics


def run():
    """benchmarks.run entry: smoke scale, speedup + cache asserted."""
    return main(["--smoke"])


if __name__ == "__main__":
    main()
