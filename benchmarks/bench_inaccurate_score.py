"""Paper Fig. 4 / §6.5: stochasticity helps under inaccurate score models.

Reproduction + mechanism refinement. We emulate the inaccurate score with
a random-feature error field of controllable RMS (delta) AND controllable
ROUGHNESS (frequency scale of the features):

  - ROUGH error (freq >= 4: decorrelates over short state distances, like
    a jagged under-fit network): tau > 0 WINS — the SDE's re-noising
    decorrelates consecutive model errors so they average out along the
    trajectory, while the ODE's smooth path integrates them coherently.
    This reproduces Fig. 4's trend and identifies WHEN it holds.
  - SMOOTH error (freq = 1: a systematic bias): tau = 0 wins — both ODE
    and SDE integrate the same bias; extra noise only adds variance.
    Negative control, recorded as a boundary of the paper's claim
    (Appendix C's (tau + 1/tau)^2 Girsanov bound is loose here).
"""

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import SASolverConfig, timestep_grid
from repro.core.coefficients import build_tables
from repro.core.solver import sample as sa_sample

from .common import GMM_TARGET, SCHED, print_table, prior, quality

TAUS = [0.0, 0.4, 0.8, 1.2]
NFE = 31


def _perturbed(delta: float, freq: float, seed: int = 0, n_features: int = 64):
    base = GMM_TARGET.model_fn(SCHED, "data")
    rng = np.random.default_rng(seed)
    W = jnp.asarray(rng.normal(size=(2, n_features)) * freq / np.sqrt(2))
    b = jnp.asarray(rng.uniform(0, 2 * np.pi, size=(n_features,)))
    V = jnp.asarray(rng.normal(size=(n_features, 2)) * np.sqrt(2.0 / n_features))

    def wrapped(x, t):
        return base(x, t) + delta * (jnp.cos(x @ W + b) @ V)

    return wrapped


def _sweep(model_fn, nfe=NFE):
    out = {}
    for tau in TAUS:
        ts = timestep_grid(SCHED, nfe - 1, kind="logsnr")
        tb = build_tables(SCHED, ts, tau=tau, predictor_order=3,
                          corrector_order=3)
        cfg = SASolverConfig(n_steps=nfe - 1, predictor_order=3,
                             corrector_order=3, tau=tau, denoise_final=False)
        x = sa_sample(model_fn, prior(), jax.random.PRNGKey(0), tb, cfg)
        out[tau] = quality(x)["sw2"]
    return out


def run():
    rows, best = [], {}
    for freq, delta in [(10.0, 0.0), (10.0, 0.2), (10.0, 0.35), (4.0, 0.35),
                        (1.0, 0.35)]:
        vals = _sweep(_perturbed(delta, freq))
        best[(freq, delta)] = min(vals, key=vals.get)
        rows.append([freq, delta] + [vals[t] for t in TAUS])
    print_table(
        f"Fig. 4 analogue: sliced-W2 vs (error roughness, delta, tau), NFE={NFE}",
        ["freq", "delta"] + [f"tau{t}" for t in TAUS], rows)
    print("best tau per (freq, delta):", best)

    # clean model at this NFE: determinism wins (paper Fig. 1 low-NFE trend)
    assert best[(10.0, 0.0)] == 0.0
    # rough inaccurate score: stochasticity wins (Fig. 4's claim)
    assert best[(10.0, 0.35)] > 0.0
    assert best[(4.0, 0.35)] > 0.0
    # smooth bias: stochasticity cannot help (boundary of the claim)
    assert best[(1.0, 0.35)] == 0.0
    return rows


if __name__ == "__main__":
    run()
