"""Paper Table 1: data- vs noise-prediction under the SDE sampler (tau=1).

Claim reproduced: the data parameterization converges dramatically better
at low NFE (the paper's 20-NFE noise-pred FID is 310 vs 3.88), because its
injected-noise variance is strictly smaller (Cor. A.2)."""

from .common import print_table, quality, sa_run


def run():
    rows = []
    for nfe in (10, 20, 40, 60, 80):
        r = {"nfe": nfe}
        for param in ("noise", "data"):
            x = sa_run(nfe, 3, 3, tau=1.0, parameterization=param)
            r[param] = quality(x)["sw2"]
        rows.append([nfe, r["noise"], r["data"]])
    print_table("Table 1 analogue: parameterization (sliced-W2, tau=1, P3C3)",
                ["NFE", "noise-pred", "data-pred"], rows)
    assert rows[0][1] > rows[0][2], "data-pred must win at low NFE"
    return rows


if __name__ == "__main__":
    run()
