"""Program-autotuner search: budgeted black-box search vs the
hand-enumerated ``nfe8-gmm`` preset, with throughput accounting.

    PYTHONPATH=src python benchmarks/bench_program_search.py [--smoke]

``bench_step_programs`` sweeps a *hand-enumerated* candidate list;
this benchmark runs the :mod:`repro.tune` subsystem over the same space:
coordinate descent + evolutionary tau refinement per mode-pattern unit,
candidates stacked into vmapped device dispatches, budget quoted in
NFE-equivalents. The search optimizes a small noisy objective (its
per-candidate cost), then the top finishers are re-ranked at validation
scale — the standard tune/validate split.

Contracts asserted (this benchmark is the autotuner's regression gate):

- **compile economy**: the whole search performs at most 2 executor
  compiles per warm-start mode pattern (in practice exactly one per
  *distinct* pattern — candidates inside a unit are table data);
- **quality**: the searched NFE<=8 program is no worse than the
  hand-enumerated ``nfe8-gmm`` preset at validation scale, and (full
  run) meets the absolute target sliced-W2 <= 0.024;
- **throughput** is recorded: candidates/s, NFE-equivalents/s,
  dispatches, compiles — into ``BENCH_RESULTS.json`` via
  ``benchmarks.run``.
"""

import argparse
import json
import time

import jax
import numpy as np

from repro.core.metrics import sliced_w2
from repro.core.programs import StepProgram, program_preset_for_nfe
from repro.core.samplers import SamplerSpec, build_plan, get_family
from repro.core.samplers import sample as plan_sample
from repro.tune import SearchConfig, run_search

try:  # python -m benchmarks.run
    from .common import data_model, target_samples
except ImportError:  # python benchmarks/bench_program_search.py
    from common import data_model, target_samples

NFE_BUDGET = 8
SW2_TARGET = 0.024  # absolute quality bar at validation scale (full run)


def _spec_of(prog: StepProgram, config: SearchConfig) -> SamplerSpec:
    """The exact spec the evaluator scored ``prog`` under (width floor +
    config spec_kw), so validation re-scores what the search ran."""
    if prog.width < config.max_order:
        prog = prog.replace(width=config.max_order)
    return SamplerSpec.from_nfe(config.family, config.nfe, program=prog,
                                **config.spec_kw)


def validate(spec: SamplerSpec, n: int, seeds, proj_keys) -> float:
    """Large-sample sliced-W2 vs GMM ground truth (the bench metric —
    same protocol as bench_step_programs)."""
    plan = build_plan(spec)
    model = data_model("data")
    vals = []
    for s in seeds:
        x_T = jax.random.normal(jax.random.PRNGKey(100 + s), (n, 2))
        x = plan_sample(plan, model, x_T, jax.random.PRNGKey(s),
                        model_key="tune-bench")
        tgt = target_samples(jax.random.PRNGKey(200 + s), n)
        vals.extend(float(sliced_w2(x, tgt, jax.random.PRNGKey(pk)))
                    for pk in proj_keys)
    return float(np.mean(vals))


def run(smoke: bool = False) -> dict:
    config = SearchConfig(
        family="sa", nfe=NFE_BUDGET, seed=0,
        budget=900 if smoke else 4000,
        n_samples=256 if smoke else 512,
        n_seeds=2 if smoke else 4,
        evo_generations=1 if smoke else 3,
        cd_passes=1 if smoke else 2)
    val_n = 2048 if smoke else 8192
    val_seeds = (0,) if smoke else (0, 1, 2)
    proj_keys = (13,) if smoke else (13, 17)
    rerank_k = 4 if smoke else 8

    # -- search ----------------------------------------------------------
    t0 = time.perf_counter()
    result = run_search(config, log=print)
    search_s = time.perf_counter() - t0
    stats = result.stats
    assert result.best_program is not None, "search evaluated nothing"
    print(f"\nsearch: {stats['candidates']} candidates in {search_s:.1f}s "
          f"({stats['candidates'] / search_s:.1f}/s, "
          f"{stats['nfe_spent'] / search_s:.0f} NFE-eq/s, "
          f"{stats['dispatches']} dispatches, "
          f"{stats['compiles']} compiles) -> best "
          f"{result.best_score:.5f} on the search objective")

    # -- compile economy: <= 2 executors per warm-start mode pattern ----
    family = get_family(config.family)
    patterns = {
        (family.statics(_spec_of(program_preset_for_nfe(
            name, config.nfe, tau=config.tau), config)),)
        for name in config.resolved_presets()}
    assert stats["compiles"] <= 2 * len(patterns), (
        f"search compiled {stats['compiles']} executors for "
        f"{len(patterns)} mode patterns — candidates must be table data")

    # -- validation re-rank: top-K search finishers + the preset --------
    preset = program_preset_for_nfe("nfe8-gmm", config.nfe, tau=config.tau)
    preset_sw2 = validate(_spec_of(preset, config), val_n, val_seeds,
                          proj_keys)
    ranked = sorted(result.state["history"], key=lambda h: h["score"])
    top, seen = [], {preset.to_json()}
    for h in ranked:
        p = StepProgram.from_json(h["program"])
        if p.to_json() not in seen:
            seen.add(p.to_json())
            top.append(p)
        if len(top) >= rerank_k:
            break
    scored = [(preset, preset_sw2)]
    scored += [(p, validate(_spec_of(p, config), val_n, val_seeds,
                            proj_keys)) for p in top]
    winner, winner_sw2 = min(scored, key=lambda r: r[1])

    print(f"validation (n={val_n}): preset nfe8-gmm {preset_sw2:.4f}, "
          f"searched winner {winner_sw2:.4f}")
    assert winner_sw2 <= preset_sw2 + 1e-12, (
        f"searched program must be no worse than the nfe8-gmm preset "
        f"({winner_sw2:.4f} vs {preset_sw2:.4f})")
    if not smoke:
        assert winner_sw2 <= SW2_TARGET, (
            f"searched program missed the absolute target "
            f"({winner_sw2:.4f} > {SW2_TARGET})")

    return {
        "nfe_budget": NFE_BUDGET,
        "metric": "sliced_w2_gmm",
        "search_budget_nfe_eq": config.budget,
        "search_best_objective": result.best_score,
        "search_s": round(search_s, 3),
        "candidates": stats["candidates"],
        "candidates_per_s": round(stats["candidates"] / search_s, 2),
        "nfe_eq_per_s": round(stats["nfe_spent"] / search_s, 1),
        "dispatches": stats["dispatches"],
        "compiles": stats["compiles"],
        "mode_patterns": len(patterns),
        "validation_n": val_n,
        "preset_nfe8_gmm_sw2": preset_sw2,
        "winner_sw2": winner_sw2,
        "winner_program": json.loads(winner.to_json()),
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small budget / sample counts (CI)")
    args = ap.parse_args(argv)
    out = run(smoke=args.smoke)
    print(json.dumps(out, indent=2, sort_keys=True))
    print("program-search bench OK: searched program matches/beats the "
          "hand preset; compile economy held")


if __name__ == "__main__":
    main()
