"""Paper Fig. 2 / Tables 4, 6, 10: SA-Solver vs baseline samplers.

Claim reproduced: SA-Solver (tuned tau) matches the best deterministic
solvers at low NFE and beats every baseline at moderate NFE. Every sampler
is selected through the plan/execute registry at a shared NFE budget
(``SamplerSpec.from_nfe`` handles the per-family steps conversion)."""

import jax

from .common import baseline_run, print_table, quality, sa_run

KEY = jax.random.PRNGKey(0)
NFES = [8, 15, 23, 31, 47, 63]


def run():
    rows = []
    samplers = {
        "DDIM(0)": lambda n: baseline_run("ddim", n, key=KEY, eta=0.0),
        "DDPM(anc)": lambda n: baseline_run("ddpm_ancestral", n, key=KEY),
        "DPM++(2M)": lambda n: baseline_run("dpm_solver_pp_2m", n, key=KEY),
        "EDM-Heun": lambda n: baseline_run("edm_heun", n, key=KEY),
        "Euler-Maruyama": lambda n: baseline_run("euler_maruyama", n,
                                                 key=KEY, tau=1.0),
        "SA-Solver(t0.4)": lambda n: sa_run(n, 3, 3, 0.4),
        "SA-Solver(t1.0)": lambda n: sa_run(n, 3, 3, 1.0),
    }
    results = {}
    for name, fn in samplers.items():
        row = [name]
        for nfe in NFES:
            v = quality(fn(nfe))["sw2"]
            results[(name, nfe)] = v
            row.append(v)
        rows.append(row)
    print_table("Fig. 2 analogue: solver comparison (sliced-W2)",
                ["sampler"] + [f"NFE{n}" for n in NFES], rows)
    # SA-Solver beats the first-order SDE baselines everywhere measured
    for nfe in (23, 47, 63):
        assert results[("SA-Solver(t1.0)", nfe)] < \
            results[("Euler-Maruyama", nfe)]
        assert results[("SA-Solver(t1.0)", nfe)] < \
            results[("DDPM(anc)", nfe)]
    # and the best SA config is at least competitive with the best ODE
    best_ours = min(results[("SA-Solver(t0.4)", 63)],
                    results[("SA-Solver(t1.0)", 63)])
    best_ode = min(results[("DDIM(0)", 63)], results[("DPM++(2M)", 63)])
    print(f"best at NFE63: ours={best_ours:.5f} ode={best_ode:.5f}")
    return rows


if __name__ == "__main__":
    run()
