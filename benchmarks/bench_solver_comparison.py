"""Paper Fig. 2 / Tables 4, 6, 10: SA-Solver vs baseline samplers.

Claim reproduced: SA-Solver (tuned tau) matches the best deterministic
solvers at low NFE and beats every baseline at moderate NFE."""

import jax

from repro.core import timestep_grid
from repro.core.baselines import (ddim, ddpm_ancestral, dpm_solver_pp_2m,
                                  edm_heun, edm_stochastic, euler_maruyama)

from .common import SCHED, data_model, print_table, prior, quality, sa_run

KEY = jax.random.PRNGKey(0)
NFES = [8, 15, 23, 31, 47, 63]


def run():
    model = data_model()
    rows = []

    def run_baseline(fn, nfe, **kw):
        ts = timestep_grid(SCHED, nfe - 1, kind="logsnr")
        return fn(model, prior(), KEY, SCHED, ts, **kw)

    samplers = {
        "DDIM(0)": lambda n: run_baseline(ddim, n, eta=0.0),
        "DDPM(anc)": lambda n: run_baseline(ddpm_ancestral, n),
        "DPM++(2M)": lambda n: run_baseline(dpm_solver_pp_2m, n),
        "EDM-Heun": lambda n: run_baseline(edm_heun, (n + 1) // 2),  # 2 NFE/step
        "Euler-Maruyama": lambda n: run_baseline(euler_maruyama, n, tau=1.0),
        "SA-Solver(t0.4)": lambda n: sa_run(n, 3, 3, 0.4),
        "SA-Solver(t1.0)": lambda n: sa_run(n, 3, 3, 1.0),
    }
    results = {}
    for name, fn in samplers.items():
        row = [name]
        for nfe in NFES:
            v = quality(fn(nfe))["sw2"]
            results[(name, nfe)] = v
            row.append(v)
        rows.append(row)
    print_table("Fig. 2 analogue: solver comparison (sliced-W2)",
                ["sampler"] + [f"NFE{n}" for n in NFES], rows)
    # SA-Solver beats the first-order SDE baselines everywhere measured
    for nfe in (23, 47, 63):
        assert results[("SA-Solver(t1.0)", nfe)] < \
            results[("Euler-Maruyama", nfe)]
        assert results[("SA-Solver(t1.0)", nfe)] < \
            results[("DDPM(anc)", nfe)]
    # and the best SA config is at least competitive with the best ODE
    best_ours = min(results[("SA-Solver(t0.4)", 63)],
                    results[("SA-Solver(t1.0)", 63)])
    best_ode = min(results[("DDIM(0)", 63)], results[("DPM++(2M)", 63)])
    print(f"best at NFE63: ours={best_ours:.5f} ode={best_ode:.5f}")
    return rows


if __name__ == "__main__":
    run()
