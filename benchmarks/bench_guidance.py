"""Guidance-sweep benchmark: classifier-free guidance through the serve
engine at a sweep of scales, with the compile-cache contract asserted.

    PYTHONPATH=src python benchmarks/bench_guidance.py --smoke
    PYTHONPATH=src python benchmarks/bench_guidance.py --requests 24

The claim under test is the denoiser adapter's serving contract: the
guidance scale (and the conditioning values) are *traced data*, so after
the engine warms a guided bucket once, serving any scale — 0.0 through
7.5 — adds ZERO compile-cache misses and zero retraces. A CFG hot path
that silently recompiled per scale would halve (or worse) serving
throughput; this is the guard. Also reports the honest CFG cost model:
``network_evals == 2 x model_evals`` for guided requests.

Model: the exact GMM eps-prediction oracle wrapped in a Denoiser
(``repro.kernels.ref.denoiser_oracles``) — the adapter+serve overhead is
measured without backbone noise, matching the other oracle benchmarks.
"""

import argparse
import time


def _args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes; assert the cache contract (CI)")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--nfe", type=int, default=None)
    ap.add_argument("--points", type=int, default=None,
                    help="latent points per request")
    return ap.parse_args(argv)


SCALES = (0.0, 0.5, 1.0, 1.5, 3.0, 7.5)


def main(argv=None):
    args = _args(argv)
    import jax.numpy as jnp
    from repro.core import Denoiser, get_schedule
    from repro.core.samplers import (SamplerSpec, clear_compile_cache,
                                     compile_cache_stats)
    from repro.kernels.ref import denoiser_oracles
    from repro.serve import ServeEngine

    try:
        from .common import print_table
    except ImportError:
        from common import print_table

    n_req = args.requests or (6 if args.smoke else 18)
    nfe = args.nfe or (6 if args.smoke else 15)
    pts = args.points or (64 if args.smoke else 256)
    schedule = get_schedule("vp_linear")
    nets = denoiser_oracles(schedule)
    denoiser = Denoiser(nets["eps"], schedule, prediction="eps",
                        guidance=True)
    spec = SamplerSpec.from_nfe(
        "sa", nfe, schedule=schedule, predictor_order=3, corrector_order=1,
        tau=0.6, prediction="eps", guidance=True)
    shape = (pts, 2)
    cond = jnp.asarray([1.0, -1.0], jnp.float32)

    engine = ServeEngine(denoiser, bucket_sizes=(max(2, n_req // 2),),
                         model_key="bench-guidance")

    def serve_at(scale, base_rid):
        for i in range(n_req):
            engine.submit(spec, shape, rid=base_rid + i,
                          cond=cond * (i + 1), guidance_scale=scale)
        t0 = time.perf_counter()
        res = engine.run()
        dt = time.perf_counter() - t0
        assert len(res) == n_req
        return dt

    clear_compile_cache()
    serve_at(1.0, 0)                       # cold: bucket warmup compile
    warmed = compile_cache_stats()

    rows, sweep_s = [], 0.0
    for j, s in enumerate(SCALES):
        dt = serve_at(s, 1000 * (j + 1))
        sweep_s += dt
        rows.append([f"scale={s}", n_req / dt, n_req * spec.nfe / dt,
                     n_req * spec.network_nfe / dt,
                     compile_cache_stats()["misses"]])
    after = compile_cache_stats()
    new_misses = after["misses"] - warmed["misses"]

    print_table(
        f"guidance-scale sweep ({n_req} req/scale, NFE={spec.nfe}, "
        f"network NFE={spec.network_nfe}, warm bucket)",
        ["scale", "req/s", "model-evals/s", "network-evals/s",
         "cum. compiles"], rows)
    st = engine.stats()
    print(f"\n### cache contract\nafter warmup: {warmed}\n"
          f"after {len(SCALES)}-scale sweep: {after}\n"
          f"new misses across scales: {new_misses}\n"
          f"CFG cost: {st['network_evals']} network evals for "
          f"{st['model_evals']} guided evals (2x, honest accounting)")
    assert st["network_evals"] == 2 * st["model_evals"]
    if args.smoke:
        assert new_misses == 0, (
            f"guidance sweep re-compiled ({new_misses} new misses) — the "
            "CFG serving hot path regressed to retrace-per-scale")
        assert after["hits"] > warmed["hits"]
        print("smoke OK: zero compile-cache misses across guidance scales")
    return {
        "requests_per_scale": n_req, "nfe": spec.nfe,
        "network_nfe": spec.network_nfe, "scales": list(SCALES),
        "sweep_s": sweep_s, "new_misses_across_scales": new_misses,
        "requests_per_s": n_req * len(SCALES) / sweep_s if sweep_s else 0.0,
    }


def run():
    """benchmarks.run entry: smoke scale, cache contract asserted."""
    return main(["--smoke"])


if __name__ == "__main__":
    main()
