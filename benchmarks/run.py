"""Benchmark aggregator: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --only tau_sweep
"""

import argparse
import sys
import time

SECTIONS = [
    ("parameterization", "Table 1: data vs noise prediction"),
    ("pc_ablation", "Table 2: predictor/corrector ablation"),
    ("tau_sweep", "Fig 1: tau x NFE sweep"),
    ("solver_comparison", "Fig 2: solver comparison"),
    ("convergence_order", "Thm 5.1/5.2: convergence order"),
    ("inaccurate_score", "Fig 4: inaccurate score"),
    ("kernels", "kernel micro-benchmarks"),
    ("solver_overhead", "solver bookkeeping overhead"),
    ("serving", "serve engine: bucket throughput + compile-cache contract"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    t00 = time.time()
    failures = []
    for name, desc in SECTIONS:
        if args.only and args.only != name:
            continue
        print(f"\n{'='*72}\n== bench_{name}: {desc}\n{'='*72}")
        sys.stdout.flush()
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.bench_{name}",
                             fromlist=["run"])
            mod.run()
            print(f"[bench_{name} done in {time.time()-t0:.1f}s]")
        except AssertionError as e:
            failures.append((name, str(e)))
            print(f"!! bench_{name} CLAIM FAILED: {e}")
        sys.stdout.flush()
    print(f"\ntotal bench time {time.time()-t00:.1f}s")
    if failures:
        print(f"{len(failures)} claim failures: {[f[0] for f in failures]}")
        sys.exit(1)
    print("all paper-claim checks passed")


if __name__ == "__main__":
    main()
