"""Benchmark aggregator: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --only tau_sweep
    PYTHONPATH=src python -m benchmarks.run --json BENCH_RESULTS.json

Every run appends a machine-readable record to ``BENCH_RESULTS.json`` at
the repo root (override with ``--json``; ``--json ''`` disables): the
perf trajectory this repo accumulates across PRs. Each record carries
per-section wall time, pass/fail status, and whatever metrics dict a
section's ``run()`` returns — so regressions are diffable by tooling, not
just eyeballed from stdout.
"""

import argparse
import json
import os
import sys
import time

SECTIONS = [
    ("parameterization", "Table 1: data vs noise prediction"),
    ("pc_ablation", "Table 2: predictor/corrector ablation"),
    ("tau_sweep", "Fig 1: tau x NFE sweep"),
    ("solver_comparison", "Fig 2: solver comparison"),
    ("convergence_order", "Thm 5.1/5.2: convergence order"),
    ("inaccurate_score", "Fig 4: inaccurate score"),
    ("kernels", "kernel micro-benchmarks"),
    ("solver_overhead", "solver bookkeeping overhead"),
    ("hotpath", "hot path: ring vs concat history HBM bytes + latency"),
    ("step_programs", "step-program search: per-interval order/mode/tau "
     "vs the fixed default at NFE<=8"),
    ("program_search", "autotuner: budgeted program search vs the hand "
     "preset + search throughput"),
    ("serving", "serve engine: bucket throughput + compile-cache contract"),
    ("continuous", "continuous batching: step vs solve scheduler on a "
     "straggler mix + churn cache contract"),
    ("faults", "fault tolerance: goodput + bitwise blast radius under "
     "an injected NaN/raise/latency mix"),
    ("guidance", "denoiser adapter: CFG scale sweep + cache contract"),
    ("e2e_dit", "end-to-end DiT sampling: bf16 fused ring HBM, sharded "
     "CFG, feature caching"),
    ("families", "solver families: quality vs NFE per registry family "
     "on the GMM oracle"),
]

DEFAULT_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_RESULTS.json")


def _append_record(path: str, record: dict) -> None:
    """Accumulate into a JSON list-of-runs (corrupt/legacy -> restart)."""
    runs = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                runs = json.load(f)
            if not isinstance(runs, list):
                runs = []
        except (json.JSONDecodeError, OSError):
            runs = []
    runs.append(record)
    with open(path, "w") as f:
        json.dump(runs, f, indent=2, sort_keys=True)
        f.write("\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default=DEFAULT_JSON,
                    help="perf-trajectory file to append this run's "
                    "machine-readable record to ('' disables)")
    args = ap.parse_args()
    t00 = time.time()
    failures = []
    sections = []
    for name, desc in SECTIONS:
        if args.only and args.only != name:
            continue
        print(f"\n{'='*72}\n== bench_{name}: {desc}\n{'='*72}")
        sys.stdout.flush()
        t0 = time.time()
        status, metrics, err = "pass", None, None
        try:
            mod = __import__(f"benchmarks.bench_{name}",
                             fromlist=["run"])
            ret = mod.run()
            metrics = ret if isinstance(ret, dict) else None
            print(f"[bench_{name} done in {time.time()-t0:.1f}s]")
        except AssertionError as e:
            status, err = "claim_failed", str(e)
            failures.append((name, str(e)))
            print(f"!! bench_{name} CLAIM FAILED: {e}")
        except Exception as e:  # crash != failed claim; keep the record
            status, err = "error", f"{type(e).__name__}: {e}"
            failures.append((name, err))
            print(f"!! bench_{name} ERRORED: {err}")
        sections.append({
            "name": name, "desc": desc, "seconds": round(time.time() - t0, 3),
            "status": status,
            **({"metrics": metrics} if metrics else {}),
            **({"error": err} if err else {}),
        })
        sys.stdout.flush()
    total_s = time.time() - t00
    print(f"\ntotal bench time {total_s:.1f}s")
    if args.json:
        record = {
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
            "total_s": round(total_s, 3),
            "only": args.only,
            "sections": sections,
            "n_failures": len(failures),
        }
        _append_record(args.json, record)
        print(f"appended run record ({len(sections)} sections) to "
              f"{args.json}")
    if failures:
        print(f"{len(failures)} claim failures: {[f[0] for f in failures]}")
        sys.exit(1)
    print("all paper-claim checks passed")


if __name__ == "__main__":
    main()
