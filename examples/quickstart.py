"""Quickstart: sample a Gaussian-mixture through the sampler registry.

    PYTHONPATH=src python examples/quickstart.py

Uses the analytic oracle (exact x0-posterior) as the "diffusion model", so
the solver is the only approximation — swap ``model_fn`` for any network
with the same (x, t) -> x0-hat signature. Any registered sampler name
works in ``make_sampler`` ("sa", "ddim", "dpm_solver_pp_2m", ...); the
``nfe=`` keyword fixes the model-evaluation budget across all of them.
"""

import jax

from repro.core import GMM, get_schedule, list_samplers, make_sampler
from repro.core.metrics import sliced_w2


def main():
    schedule = get_schedule("vp_linear")
    target = GMM.default_2d()
    model_fn = target.model_fn(schedule, "data")   # exact E[x0 | x_t]

    sampler = make_sampler(
        "sa",                  # any of list_samplers()
        schedule=schedule,
        nfe=20,                # model-evaluation budget (PEC: 19 steps + 1)
        predictor_order=3,
        corrector_order=3,
        tau=1.0,               # full SDE stochasticity
    )

    x_T = sampler.init_noise(jax.random.PRNGKey(0), (4096, 2))
    x_0 = sampler.sample(model_fn, x_T, jax.random.PRNGKey(1))

    ref = target.sample(jax.random.PRNGKey(2), 4096)
    print(f"registry: {list_samplers()}")
    print(f"sampled {x_0.shape[0]} points with NFE={sampler.nfe}")
    print(f"sliced-W2 to target: {sliced_w2(x_0, ref, jax.random.PRNGKey(3)):.5f}")
    print(f"(prior baseline:     "
          f"{sliced_w2(x_T, ref, jax.random.PRNGKey(3)):.5f})")


if __name__ == "__main__":
    main()
