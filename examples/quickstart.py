"""Quickstart: sample a Gaussian-mixture with SA-Solver in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py

Uses the analytic oracle (exact x0-posterior) as the "diffusion model", so
the solver is the only approximation — swap ``model_fn`` for any network
with the same (x, t) -> x0-hat signature.
"""

import jax
import jax.numpy as jnp

from repro.core import GMM, SASolver, SASolverConfig, get_schedule
from repro.core.metrics import sliced_w2


def main():
    schedule = get_schedule("vp_linear")
    target = GMM.default_2d()
    model_fn = target.model_fn(schedule, "data")   # exact E[x0 | x_t]

    config = SASolverConfig(
        n_steps=19,            # NFE = 20
        predictor_order=3,
        corrector_order=3,
        tau=1.0,               # full SDE stochasticity
    )
    solver = SASolver(schedule, config)

    x_T = solver.init_noise(jax.random.PRNGKey(0), (4096, 2))
    x_0 = solver.sample(model_fn, x_T, jax.random.PRNGKey(1))

    ref = target.sample(jax.random.PRNGKey(2), 4096)
    print(f"sampled {x_0.shape[0]} points with NFE={config.nfe}")
    print(f"sliced-W2 to target: {sliced_w2(x_0, ref, jax.random.PRNGKey(3)):.5f}")
    print(f"(prior baseline:     "
          f"{sliced_w2(x_T, ref, jax.random.PRNGKey(3)):.5f})")


if __name__ == "__main__":
    main()
