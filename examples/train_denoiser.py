"""End-to-end driver: train a DiT-S denoiser (~20M params) for a few
hundred steps on a synthetic latent-field task, then sample it with
SA-Solver at several (tau, NFE) settings — the paper's full pipeline.

    PYTHONPATH=src python examples/train_denoiser.py --steps 300

With --steps 300 on this container's CPU this takes a few minutes; the
training loop is the fault-tolerant one (checkpoints to --ckpt, auto-
resume on rerun).
"""

import argparse

import jax
import jax.numpy as jnp

from repro.core import SASolver, SASolverConfig, get_schedule
from repro.core.metrics import sliced_w2
from repro.data import latent_batch
from repro.models import build_model, init_params
from repro.configs import get_smoke
from repro.optim import (adamw, apply_updates, chain, clip_by_global_norm,
                         linear_warmup_cosine)
from repro.runtime import TrainLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--ckpt", default="/tmp/repro_denoiser")
    ap.add_argument("--fresh", action="store_true")
    args = ap.parse_args()

    if args.fresh:
        import shutil
        shutil.rmtree(args.ckpt, ignore_errors=True)

    sched = get_schedule("vp_linear")
    import dataclasses
    cfg = dataclasses.replace(get_smoke("dit-s"), n_layers=4, d_model=128,
                              d_ff=512, n_heads=4, n_kv_heads=4,
                              dtype=jnp.float32)
    model = build_model(cfg)
    dz, S = cfg.denoiser_latent, args.seq
    opt = chain(clip_by_global_norm(1.0),
                adamw(linear_warmup_cosine(2e-3, 20, args.steps),
                      weight_decay=0.0))

    def init_state():
        params = init_params(jax.random.PRNGKey(0), model.param_defs(),
                             jnp.float32)
        return {"params": params, "opt": opt.init(params),
                "step": jnp.zeros((), jnp.int32)}

    def denoise_loss(params, x0, key):
        kt, kn = jax.random.split(key)
        t = jax.random.uniform(kt, (x0.shape[0],), minval=1e-3, maxval=1.0)
        eps = jax.random.normal(kn, x0.shape)
        a = sched.alpha_j(t)[:, None, None]
        s = sched.sigma_j(t)[:, None, None]
        pred = model.denoise(params, a * x0 + s * eps, t)
        return jnp.mean((pred - x0) ** 2)

    @jax.jit
    def train_step(state, batch):
        key = jax.random.fold_in(jax.random.PRNGKey(42), state["step"])
        loss, grads = jax.value_and_grad(denoise_loss)(
            state["params"], batch["x0"], key)
        upd, opt_state = opt.update(grads, state["opt"], state["params"],
                                    state["step"])
        return ({"params": apply_updates(state["params"], upd),
                 "opt": opt_state, "step": state["step"] + 1},
                {"loss": loss})

    class Batches:
        def __init__(self):
            self.step = 0

        def __iter__(self):
            return self

        def __next__(self):
            b = latent_batch(dz, S, args.batch, step=self.step)
            self.step += 1
            return {"x0": jnp.asarray(b["x0"])}

    loop = TrainLoop(train_step, init_state, args.ckpt, save_every=100)
    state, hist = loop.run(Batches(), args.steps, log_every=50)
    print(f"\ntraining: loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")

    # ---- sample with SA-Solver at several settings --------------------
    params = state["params"]
    data = jnp.asarray(latent_batch(dz, S, 512, step=10_000)["x0"])
    key = jax.random.PRNGKey(9)
    print("\nSA-Solver sampling (sliced-W2 to held-out data, lower=better):")
    for tau, nfe in [(0.0, 10), (0.4, 10), (0.0, 30), (1.0, 30)]:
        solver = SASolver(sched, SASolverConfig(
            n_steps=nfe - 1, predictor_order=3, corrector_order=3, tau=tau))
        xT = solver.init_noise(jax.random.PRNGKey(5), (512, S, dz))
        x0 = solver.sample(lambda x, t: model.denoise(params, x, t),
                           xT, jax.random.PRNGKey(6))
        d = sliced_w2(x0.reshape(512, -1), data.reshape(512, -1), key)
        print(f"  tau={tau:<4} NFE={nfe:<3} sliced-W2={d:.4f}")
    d0 = sliced_w2(xT.reshape(512, -1), data.reshape(512, -1), key)
    print(f"  (prior noise baseline: {d0:.4f})")


if __name__ == "__main__":
    main()
