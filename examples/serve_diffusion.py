"""Batched diffusion serving: requests arrive with different prompts
(conditioning latents), get micro-batched, and are sampled TOGETHER in one
SA-Solver loop — the serving pattern the dry-run lowers at 512 devices.

    PYTHONPATH=src python examples/serve_diffusion.py --requests 12 --nfe 15

Demonstrates: request batching with ragged arrival, per-request RNG
(fold_in by request id — no cross-request noise correlation), and a
backbone selected by --arch (any zoo member in denoiser mode).
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.core import SASolver, SASolverConfig, get_schedule
from repro.models import build_model, init_params


class DiffusionServer:
    """Compile once per (batch, seq) bucket; serve request batches."""

    def __init__(self, arch: str, nfe: int, tau: float, latent: int = 8):
        cfg = get_smoke(arch)
        if getattr(cfg, "denoiser_latent", None) is None:
            cfg = dataclasses.replace(cfg, denoiser_latent=latent)
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = init_params(jax.random.PRNGKey(0),
                                  self.model.param_defs(), jnp.float32)
        self.solver = SASolver(get_schedule("vp_linear"), SASolverConfig(
            n_steps=nfe - 1, predictor_order=3, corrector_order=1, tau=tau))
        self._compiled = {}

    def _fn(self, batch, seq):
        key = (batch, seq)
        if key not in self._compiled:
            dz = self.cfg.denoiser_latent

            def serve(request_ids):
                def one_noise(rid):
                    return self.solver.init_noise(
                        jax.random.fold_in(jax.random.PRNGKey(7), rid),
                        (seq, dz))
                xT = jax.vmap(one_noise)(request_ids)
                k = jax.random.fold_in(jax.random.PRNGKey(8),
                                       request_ids[0])
                return self.solver.sample(
                    lambda x, t: self.model.denoise(self.params, x, t),
                    xT, k)

            self._compiled[key] = jax.jit(serve)
        return self._compiled[key]

    def serve_batch(self, request_ids, seq: int):
        fn = self._fn(len(request_ids), seq)
        return fn(jnp.asarray(request_ids))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dit-s")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--nfe", type=int, default=15)
    ap.add_argument("--tau", type=float, default=0.6)
    args = ap.parse_args()

    server = DiffusionServer(args.arch, args.nfe, args.tau)
    pending = list(range(args.requests))
    done = 0
    t0 = time.perf_counter()
    while pending:
        batch, pending = pending[:args.batch], pending[args.batch:]
        while len(batch) < args.batch:      # pad the tail bucket
            batch.append(batch[-1])
        out = jax.block_until_ready(server.serve_batch(batch, args.seq))
        assert bool(jnp.all(jnp.isfinite(out)))
        done += len(set(batch))
        print(f"served batch {sorted(set(batch))}: out {out.shape}, "
              f"std={float(jnp.std(out)):.3f}")
    dt = time.perf_counter() - t0
    print(f"\n{done} requests in {dt:.2f}s "
          f"({done * args.nfe / dt:.1f} model-evals/s, NFE={args.nfe}, "
          f"arch={server.cfg.name})")


if __name__ == "__main__":
    main()
