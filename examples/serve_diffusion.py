"""Thin client of ``repro.serve``: batched diffusion serving on the
plan/execute sampler registry.

    PYTHONPATH=src python examples/serve_diffusion.py --requests 12 --nfe 15

The engine does the heavy lifting (see ``repro/serve/__init__.py`` for the
architecture): requests are bucketed by ``(SamplerSpec, shape)``, ragged
tails are padded with *masked* lanes (no duplicate re-solves), each bucket
is AOT-compiled once, per-request RNG is ``fold_in(seed, rid)``, and
``--stream`` attaches per-step denoised previews from the trajectory hook.
This client just builds a denoiser backbone, submits a mix of requests
(two tau values — same compiled executor, different traced coefficient
tables), and prints the engine's honest throughput: model-evals/s counts
real requests only, padded lanes are reported separately.
"""

import argparse

import jax.numpy as jnp

from repro.core import get_schedule
from repro.core.samplers import SamplerSpec, list_samplers
from repro.launch.serve import build_denoiser_model_fn
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dit-s")
    ap.add_argument("--sampler", default="sa", choices=list_samplers())
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--bucket-sizes", type=lambda s: [int(b) for b in
                    s.split(",")], default=[1, 2, 4])
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--latent", type=int, default=8)
    ap.add_argument("--nfe", type=int, default=15)
    ap.add_argument("--tau", type=float, default=0.6)
    ap.add_argument("--stream", action="store_true",
                    help="also stream per-step denoised previews")
    args = ap.parse_args()

    cfg, model_fn = build_denoiser_model_fn(args.arch, args.latent,
                                            smoke=True)

    def on_result(res):
        line = f"served rid {res.rid}: x0 {res.x0.shape}, " \
               f"std={float(jnp.std(res.x0)):.3f}"
        if res.previews is not None:
            stds = ["%.2f" % float(jnp.std(p)) for p in res.previews[:6]]
            line += f", x0-preview std per step {stds}..."
        print(line)

    engine = ServeEngine(model_fn, bucket_sizes=tuple(args.bucket_sizes),
                         stream=args.stream, on_result=on_result,
                         model_key=("denoiser", cfg.name))

    schedule = get_schedule("vp_linear")
    shape = (args.seq, cfg.denoiser_latent)
    for i in range(args.requests):
        # alternate tau: same bucket statics, different traced tables —
        # the engine still compiles each bucket size exactly once
        tau = args.tau if i % 2 == 0 else min(1.0, args.tau + 0.4)
        engine.submit(SamplerSpec.from_nfe(
            args.sampler, args.nfe, schedule=schedule, predictor_order=3,
            corrector_order=1, tau=tau), shape)

    results = engine.run()
    assert len(results) == args.requests
    assert all(bool(jnp.all(jnp.isfinite(r.x0))) for r in results)

    s = engine.stats()
    print(f"\n{s['requests']} requests in {s['serve_s']:.2f}s over "
          f"{s['microbatches']} microbatches "
          f"({s['padded_slots']} padded lanes — masked, never counted)")
    print(f"{s['requests_per_s']:.2f} requests/s, "
          f"{s['model_evals_per_s']:.1f} model-evals/s "
          f"(NFE x real requests; sampler={args.sampler}, "
          f"arch={cfg.name})")
    print("compile cache:", s["compile_cache"])


if __name__ == "__main__":
    main()
