"""Batched diffusion serving on the plan/execute sampler registry:
requests arrive with different prompts (conditioning latents), get
micro-batched, and are sampled TOGETHER via ``sample_batched`` (one vmapped
solver loop, one compilation per bucket) — the serving pattern the dry-run
lowers at 512 devices.

    PYTHONPATH=src python examples/serve_diffusion.py --requests 12 --nfe 15

Demonstrates: runtime solver selection (--sampler picks any registry
entry), request batching with ragged arrival, per-request RNG (fold_in by
request id — no cross-request noise correlation), streamed intermediate
previews (--stream: per-step denoised snapshots from the trajectory hook),
and a backbone selected by --arch (any zoo member in denoiser mode).
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.core import get_schedule
from repro.core.samplers import SamplerSpec, Sampler, list_samplers
from repro.models import build_model, init_params


class DiffusionServer:
    """Plan once per sampler config; compile once per (batch, seq) bucket."""

    def __init__(self, arch: str, sampler: str, nfe: int, tau: float,
                 latent: int = 8, stream: bool = False):
        cfg = get_smoke(arch)
        if getattr(cfg, "denoiser_latent", None) is None:
            cfg = dataclasses.replace(cfg, denoiser_latent=latent)
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = init_params(jax.random.PRNGKey(0),
                                  self.model.param_defs(), jnp.float32)
        self.sampler = Sampler(SamplerSpec.from_nfe(
            sampler, nfe, schedule=get_schedule("vp_linear"),
            predictor_order=3, corrector_order=1, tau=tau))
        self.stream = stream
        # sample_batched vmaps over requests, so the model_fn sees one
        # request (seq, dz) at a time; the backbone wants a batch axis
        self._model_fn = lambda x, t: self.model.denoise(
            self.params, x[None], t)[0]

    def serve_batch(self, request_ids, seq: int):
        """One vmapped solve for the whole bucket, one RNG per request."""
        rids = jnp.asarray(request_ids)
        dz = self.cfg.denoiser_latent
        noise_keys = jax.vmap(
            lambda r: jax.random.fold_in(jax.random.PRNGKey(7), r))(rids)
        xT = jax.vmap(
            lambda k: self.sampler.init_noise(k, (seq, dz)))(noise_keys)
        solve_keys = jax.vmap(
            lambda r: jax.random.fold_in(jax.random.PRNGKey(8), r))(rids)
        return self.sampler.sample_batched(
            self._model_fn, xT, solve_keys, trajectory=self.stream)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dit-s")
    ap.add_argument("--sampler", default="sa", choices=list_samplers())
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--nfe", type=int, default=15)
    ap.add_argument("--tau", type=float, default=0.6)
    ap.add_argument("--stream", action="store_true",
                    help="also return per-step denoised previews")
    args = ap.parse_args()

    server = DiffusionServer(args.arch, args.sampler, args.nfe, args.tau,
                             stream=args.stream)
    pending = list(range(args.requests))
    done = 0
    t0 = time.perf_counter()
    while pending:
        batch, pending = pending[:args.batch], pending[args.batch:]
        while len(batch) < args.batch:      # pad the tail bucket
            batch.append(batch[-1])
        out = server.serve_batch(batch, args.seq)
        if args.stream:
            out, traj = out
            previews = jax.block_until_ready(traj["x0"])
            # stream: preview quality per step for the first request
            steps = previews.shape[1]
            stds = [float(jnp.std(previews[0, s])) for s in range(steps)]
            print(f"  stream req {batch[0]}: x0-preview std per step "
                  f"{['%.2f' % s for s in stds[:6]]}...")
        out = jax.block_until_ready(out)
        assert bool(jnp.all(jnp.isfinite(out)))
        done += len(set(batch))
        print(f"served batch {sorted(set(batch))}: out {out.shape}, "
              f"std={float(jnp.std(out)):.3f}")
    dt = time.perf_counter() - t0
    print(f"\n{done} requests in {dt:.2f}s "
          f"({done * server.sampler.nfe / dt:.1f} model-evals/s, "
          f"NFE={server.sampler.nfe}, sampler={args.sampler}, "
          f"arch={server.cfg.name})")


if __name__ == "__main__":
    main()
