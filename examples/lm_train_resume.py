"""Fault-tolerant LM training demo: train a smoke-scale assigned arch,
inject a failure, and auto-resume from the latest committed checkpoint.

    PYTHONPATH=src python examples/lm_train_resume.py --arch rwkv6-3b

Shows the full recovery path: run crashes at --fail-at, rerun picks up the
checkpoint and the loss stream continues exactly as if uninterrupted
(deterministic data pipeline + committed state).
"""

import argparse
import shutil

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.data import TokenTaskConfig, synthetic_lm_batch
from repro.models import build_model, init_params
from repro.optim import (adamw, apply_updates, chain, clip_by_global_norm,
                         global_norm)
from repro.runtime import InjectedFailure, TrainLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--fail-at", type=int, default=35)
    ap.add_argument("--ckpt", default="/tmp/repro_lm_resume")
    args = ap.parse_args()
    shutil.rmtree(args.ckpt, ignore_errors=True)

    cfg = get_smoke(args.arch)
    model = build_model(cfg)
    task = TokenTaskConfig(vocab_size=cfg.vocab_size, seq_len=64)
    opt = chain(clip_by_global_norm(1.0), adamw(1e-3))

    def init_state():
        p = init_params(jax.random.PRNGKey(0), model.param_defs(), jnp.float32)
        return {"params": p, "opt": opt.init(p),
                "step": jnp.zeros((), jnp.int32)}

    @jax.jit
    def train_step(state, batch):
        loss, grads = jax.value_and_grad(model.loss_fn)(state["params"], batch)
        upd, o = opt.update(grads, state["opt"], state["params"], state["step"])
        return ({"params": apply_updates(state["params"], upd), "opt": o,
                 "step": state["step"] + 1},
                {"loss": loss, "gnorm": global_norm(grads)})

    class Batches:
        def __init__(self):
            self.step = 0

        def __iter__(self):
            return self

        def __next__(self):
            b = synthetic_lm_batch(task, 8, self.step)
            self.step += 1
            return {k: jnp.asarray(v) for k, v in b.items()
                    if k in ("tokens", "labels")}

    loop = TrainLoop(train_step, init_state, args.ckpt, save_every=10)
    print(f"=== run 1 (will crash at step {args.fail_at}) ===")
    try:
        loop.run(Batches(), args.steps, fail_at=args.fail_at, log_every=10)
    except InjectedFailure as e:
        print(f"!! {e} — simulating node failure\n")

    print("=== run 2 (auto-resume from latest committed checkpoint) ===")
    loop2 = TrainLoop(train_step, init_state, args.ckpt, save_every=10)
    state, hist = loop2.run(Batches(), args.steps, log_every=10)
    print(f"\nrecovered and finished: final loss {hist[-1]['loss']:.4f} "
          f"(started from step {int(state['step']) - len(hist)})")


if __name__ == "__main__":
    main()
